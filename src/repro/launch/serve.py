"""Serving launcher: mesh-placed batched prefill + decode with a sharded
KV cache, quantized activation collectives, and optional prefill/decode
disaggregation.

``python -m repro.launch.serve --arch paper-lm-100m`` runs a batched
generation loop with the reduced smoke config (``--full`` lowers the real
published config instead) on a local mesh built over whatever devices exist
(1 CPU device degrades to a (1, 1) mesh; the CI multidevice job forces 8
host devices and gets a real (data, model) mesh). Params, KV cache, and
batch are explicitly placed: the ``serve_sp`` preset shards the cache over
data (batch dim) x model (sequence dim) and the residual stream over
sequence, and ``--act-transport int8`` runs the sequence-parallel
activation all-gathers as blockwise-int8 chunks + scales
(``repro.dist.collectives.act_gather``).

``--disagg`` splits the pipeline across two meshes — AutoComp's dedicated
compaction cluster, translated to serving: compute-bound prefill runs
sequence-parallel (``serve_sp``) on one half of the devices, decode runs
batch-heavy (``serve_decode``: cache resident, no per-step cache
collectives) on the other half, and the KV cache is handed off between
them once per request batch. ``--cache-transfer int8`` quantizes that
handoff blockwise along the sequence axis (s8 chunks + f32 scales on the
wire); ``--kv-storage {int8,f8}`` additionally keeps the decode-resident
cache quantized (~half the HBM: s8 + scales, or scale-free e4m3),
dequantized/upcast per block at attention read time. The knobs are
orthogonal — transfer x storage combinations, reported per decode dryrun
cell (``repro.launch.dryrun --shape decode``).

``--stream slots`` makes the handoff *continuous* (AutoComp's core lesson:
consolidation work runs concurrently with the serving it feeds, not as
stop-the-world batches): instead of prefilling a whole batch and handing
the cache to a fresh decode batch, each finished request's cache slice is
quantized/shipped/dequantized into a free row of a RUNNING decode batch
(slot admission), and the next slice's wire transfer is double-buffered
behind the current decode steps. Slots free as requests finish and are
reused by pending requests; greedy tokens are identical to the whole-batch
path.

Continuous batching: requests at different positions share one decode step
(``prompt_lens`` gives per-row lengths; positions/masks are per-row, so
padded prompt slots are never attended — same semantics the decode_attn
Pallas kernel implements on TPU).

``--workers N`` (or ``--paged``) routes serving through the *fan-in*
engine (:func:`_generate_fanin`): N independent prefill workers — each
running the same double-buffered mover — feed ONE decode slot table
through :class:`repro.dist.fanin.AdmissionArbiter` (FIFO with priority
classes, aging + hard promotion mirroring the fleet scheduler's
starvation bound, per-worker in-flight accounting, and a deterministic
tie-break: the engine blocks on the arbiter's chosen shipment instead of
racing worker completion order, so admissions replay identically under
permuted arrival). When the table is full, ``--evict`` preempts a
justified victim — the evicted request requeues with its emitted tokens
appended to its prompt and is re-prefilled on readmission (recompute
preemption; greedy tokens bit-match an uncontended run). ``--paged``
swaps the dense pad-to-horizon slot table for a *paged* one
(:class:`repro.models.registry.PagedStateStore`): rows are lists of
fixed-size pages in a shared pool with a per-slot page table, admission
ships only live pages, pages allocate on demand as a row decodes past a
page boundary, and the decode step runs *unchanged* on a dense view
gathered through the table (bit parity with the unpaged path). The page
size is a tunable axis on the kernel registry (``paged_attn``), swept by
``tune_design`` like every other kernel block. See docs/serving.md for
the full operator's guide.
"""

from __future__ import annotations

import argparse
import contextlib
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.dist import collectives, fanin
from repro.dist import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.models import registry, transformer
from repro.train import step as step_lib


def grow_cache(cache, target):
    """Grow every cache leaf to the decode-horizon shape (end-padding).

    ``target`` is the abstract decode cache, so windowed/SSM/xLSTM states
    are handled uniformly: leaves already at the target shape only cast,
    anything smaller pads with zeros at the end of each dimension (new
    slots read as empty and are masked by slot-position validity until
    written).
    """
    def grow(c, tgt):
        if c.shape == tgt.shape:
            return c.astype(tgt.dtype)
        pad = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
        return jnp.pad(c, pad).astype(tgt.dtype)

    return jax.tree.map(grow, cache, target)


def fit_cache(cache, target):
    """:func:`grow_cache` that can also *shrink*: every leaf is sliced to
    the target extent before padding. The fan-in engine needs both
    directions — a fresh paged admission ships ``ceil(len / page)`` pages,
    which may be fewer positions than the ``[1, S0]`` prefill buffer
    (the dropped tail is pad junk beyond the request's live length, which
    per-row position masks never attend), while a readmitted request's
    exact-length prefill pads up to the next page boundary.
    """
    def fit(c, tgt):
        if c.shape == tgt.shape:
            return c.astype(tgt.dtype)
        c = c[tuple(slice(0, min(s, t)) for s, t in zip(c.shape, tgt.shape))]
        pad = [(0, t - s) for s, t in zip(c.shape, tgt.shape)]
        return jnp.pad(c, pad).astype(tgt.dtype)

    return jax.tree.map(fit, cache, target)


def make_cache_transfer_step(cfg, batch: int, total: int, mode: str,
                             block: int = collectives.ACT_BLOCK):
    """Single-mesh form of the prefill->decode cache handoff.

    Returns ``transfer(cache) -> cache`` that reshards every leaf to the
    layout the active ``axis_rules`` context resolves for its logical
    axes; ``mode="int8"`` routes leaves with a sequence axis through
    ``collectives.stream_int8`` (seq-blockwise s8 chunks + scales on the
    wire, ``block`` positions per chunk), everything else (recurrent
    state, ``mode="bf16"``) moves raw. jit it with in_shardings = the
    prefill layout and out_shardings = the decode layout under
    ``axis_rules(mesh, serve_decode)`` and the compiled HLO is the
    transfer's wire — what the dryrun and the disagg mesh tests measure.
    """
    if mode not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {mode!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")
    axes = transformer.cache_axes(cfg, batch, total)

    def transfer(cache):
        def move(leaf, la):
            la = tuple(la)
            if mode == "int8" and "kv_seq" in la:
                return collectives.stream_int8(
                    leaf, *la, seq_axis=la.index("kv_seq"), block=block)
            return shd.constrain(leaf, *la)
        return jax.tree.map(move, cache, axes)
    return transfer


def make_cache_mover(cfg, batch: int, total: int, dec_mesh, dec_rules,
                     mode: str, dst_shardings):
    """Two-mesh cache handoff, built ONCE: returns ``move(cache) -> cache``
    placing a committed prefill cache (or a single request's ``batch=1``
    slice) onto the decode mesh. ``"bf16"`` is a plain ``device_put``;
    ``"int8"`` quantizes each sequence-carrying leaf blockwise along the
    sequence axis *on the prefill mesh*, moves the s8 chunks + f32 scales
    (the only cross-mesh traffic, ~1/4 the bf16 bytes), and dequantizes
    on arrival — AutoComp's compaction-output handoff, as a cache stream.
    The quantize/dequantize programs are jitted once here, so the slot
    streamer can call ``move`` per admission without recompiling.
    """
    if mode == "bf16":
        return lambda cache: jax.device_put(cache, dst_shardings)
    axes = transformer.cache_axes(cfg, batch, total)
    c_abs = transformer.abstract_cache(cfg, batch, total)
    abs_l, treedef = jax.tree.flatten(c_abs)
    axes_l = [tuple(a) for a in treedef.flatten_up_to(axes)]
    dst_l = treedef.flatten_up_to(dst_shardings)
    seq_ix = [la.index("kv_seq") if "kv_seq" in la else None for la in axes_l]
    dtypes = [x.dtype for x in abs_l]

    qs_shardings = []
    for x, si, la in zip(abs_l, seq_ix, axes_l):
        if si is None:
            qs_shardings.append(None)
            continue
        q_axes = la[:si] + la[si + 1:] + (la[si],)   # seq-last layout
        _, nb = collectives.lastdim_blocks(x.shape[si])
        s_shape = tuple(d for i, d in enumerate(x.shape) if i != si) + (nb,)
        qs_shardings.append((
            jax.sharding.NamedSharding(dec_mesh, shd.resolve_spec(
                x.shape[:si] + x.shape[si + 1:] + (x.shape[si],),
                q_axes, dec_mesh, dec_rules)),
            jax.sharding.NamedSharding(dec_mesh, shd.resolve_spec(
                s_shape, q_axes[:-1] + (None,), dec_mesh, dec_rules))))

    @jax.jit
    def quant(ls):                               # runs on the prefill mesh
        return [x if si is None
                else collectives.quantize_int8_seqaxis(x, si)
                for x, si in zip(ls, seq_ix)]

    def dequant(ls):
        return treedef.unflatten([
            x if si is None
            else collectives.dequantize_int8_seqaxis(x[0], x[1], si).astype(dt)
            for x, si, dt in zip(ls, seq_ix, dtypes)])
    dequant = jax.jit(dequant, out_shardings=dst_shardings)

    def move(cache):
        q_leaves = quant(jax.tree.leaves(cache))
        moved = []
        for x, si, dst, qs in zip(q_leaves, seq_ix, dst_l, qs_shardings):
            if si is None:
                moved.append(jax.device_put(x, dst))
            else:
                moved.append((jax.device_put(x[0], qs[0]),
                              jax.device_put(x[1], qs[1])))
        return dequant(moved)
    return move


STREAMS = ("batch", "slots")


def _default_page(base: int) -> int:
    """Page size when ``--page-size 0``: the tuned ``paged_attn`` registry
    point, capped so a row spans at least 8 pages — a near-single-page
    row degenerates to the dense pad-to-horizon layout and buys no HBM
    back, so small smoke horizons get proportionally small pages."""
    from repro.kernels.paged_attn import tuned_page_size
    return max(1, min(tuned_page_size(base), -(-base // 8)))


def _check_prompt_lens(cfg, lens: np.ndarray, b: int, s0: int,
                       max_new: int, total: int, paged: bool) -> None:
    """Loud validation of per-request lengths against the prompt buffer
    and the decode horizon.

    Bugfix: these used to be bare ``assert``s — stripped under ``-O``,
    and even when they fired they named nothing. A request longer than
    the decode horizon would silently truncate (its tail positions
    written past the cache end are dropped by the update's clamp) and
    serve wrong tokens without a word. Refuse loudly instead, in the
    same uniform style as ``registry.require``; under ``--paged`` the
    horizon cap does not apply (pages allocate on demand), so the same
    request admits.
    """
    lens = np.asarray(lens)
    if lens.shape != (b,):
        raise ValueError(f"prompt_lens shape {tuple(lens.shape)} does not "
                         f"match the batch ({b},)")
    if (lens < 1).any():
        raise ValueError("every request needs at least one prompt token; "
                         f"got prompt_lens={lens.tolist()}")
    over = np.nonzero(lens > s0)[0]
    if over.size:
        i = int(over[0])
        raise ValueError(
            f"request {i} claims {int(lens[i])} prompt tokens but the "
            f"prompt buffer holds only {s0}: the overflow was already "
            f"lost — refusing to serve a silently truncated prompt")
    if paged:
        return
    over = np.nonzero(lens + max_new > total)[0]
    if over.size:
        i = int(over[0])
        raise ValueError(
            f"request {i} needs {int(lens[i]) + max_new} positions "
            f"(prompt {int(lens[i])} + {max_new} new) but the decode "
            f"horizon is {total} for {cfg.name}: refusing to truncate — "
            f"raise --horizon, or serve --paged (pages allocate on "
            f"demand, so long requests admit instead of truncating)")


def generate(cfg, params, prompts: np.ndarray, max_new: int = 16,
             temperature: float = 0.0, seed: int = 0,
             prompt_lens: Optional[np.ndarray] = None,
             mesh=None, rules=None, act_transport: str = "bf16",
             decode_mesh=None, decode_rules=None,
             cache_transfer: str = "bf16", kv_storage: str = "bf16",
             stream: str = "batch", slots: int = 0,
             workers: int = 1, evict: str = "oldest", paged: bool = False,
             page_size: int = 0, pool_pages: int = 0, horizon: int = 0,
             priorities: Optional[np.ndarray] = None, prefill_meshes=None):
    """prompts: (B, S0) int32, right-padded when ragged. Greedy (or
    sampled) decode of ``max_new`` tokens per row.

    ``prompt_lens`` (B,) enables ragged continuous batching: row i's real
    prompt is ``prompts[i, :prompt_lens[i]]``; every row decodes from its
    own position and pad slots are masked (each row's output matches a
    solo run of its unpadded prompt). ``mesh`` places params/cache/batch
    explicitly (``rules`` defaults to the ``serve_sp`` preset);
    ``act_transport`` picks the activation all-gather wire format.

    ``decode_mesh`` disaggregates: prefill compiles on ``mesh`` (its own
    devices, ``rules``), decode on ``decode_mesh`` (``decode_rules``,
    default the batch-heavy ``serve_decode`` preset), and the prefilled
    cache crosses between them — raw under ``cache_transfer="bf16"``, as
    seq-blockwise s8 chunks + scales under ``"int8"``.
    ``kv_storage="int8"`` keeps the decode-resident cache int8 (works
    colocated too, and even without a mesh); ``"f8"`` stores scale-free
    e4m3 instead (same HBM saving, no scale leaves).

    ``stream`` picks the handoff granularity: ``"batch"`` (this function's
    body) prefills the whole batch and hands the cache to a fresh decode
    batch once; ``"slots"`` streams each request's cache slice into a
    *running* decode batch via slot admission (``slots`` = slot-table
    size, 0 = one per request) with the next slice's wire transfer
    double-buffered behind the current decode steps — see
    :func:`_generate_slots`.

    ``workers > 1`` or ``paged=True`` routes through the fan-in engine
    (:func:`_generate_fanin`): ``workers`` prefill workers (optionally on
    their own meshes via ``prefill_meshes``) feed the slot table through
    the admission arbiter; ``evict`` picks the preemption policy,
    ``priorities`` (B,) assigns admission classes (0 = most urgent), and
    ``paged``/``page_size``/``pool_pages`` swap in the paged slot cache.
    ``horizon`` caps the decode horizon in positions (0 = sized to fit):
    an unpaged request that cannot fit is refused loudly, never silently
    truncated; a paged one admits.
    """
    if stream not in STREAMS:
        raise ValueError(f"unknown stream {stream!r}; "
                         f"expected one of {STREAMS}")
    if workers > 1 or paged or prefill_meshes is not None:
        return _generate_fanin(
            cfg, params, prompts, max_new=max_new, temperature=temperature,
            seed=seed, prompt_lens=prompt_lens, mesh=mesh, rules=rules,
            act_transport=act_transport, decode_mesh=decode_mesh,
            decode_rules=decode_rules, cache_transfer=cache_transfer,
            kv_storage=kv_storage, slots=slots, workers=workers,
            evict=evict, paged=paged, page_size=page_size,
            pool_pages=pool_pages, horizon=horizon, priorities=priorities,
            prefill_meshes=prefill_meshes)
    if stream == "slots":
        return _generate_slots(
            cfg, params, prompts, max_new=max_new, temperature=temperature,
            seed=seed, prompt_lens=prompt_lens, mesh=mesh, rules=rules,
            act_transport=act_transport, decode_mesh=decode_mesh,
            decode_rules=decode_rules, cache_transfer=cache_transfer,
            kv_storage=kv_storage, slots=slots, horizon=horizon)
    b, s0 = prompts.shape
    total = s0 + max_new
    ragged = prompt_lens is not None
    lens = np.asarray(prompt_lens, np.int32) if ragged else None
    _check_prompt_lens(cfg, lens if ragged else np.full((b,), s0, np.int32),
                       b, s0, max_new, int(horizon) or total, paged=False)
    if ragged:
        # Ragged masking is only sound for full (slot == position) caches:
        # ring buffers alias a padded position's junk slot to an in-window
        # position before the row overwrites it, and SSM/xLSTM recurrent
        # states scan pad tokens in during prefill — per-row masks cannot
        # undo either. row_state families serve mixed lengths through
        # --stream slots (exact-length per-request prefill) instead.
        registry.require(cfg, "ragged", "ragged prompt_lens")
    if cache_transfer not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {cache_transfer!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")

    disagg = decode_mesh is not None
    if disagg and mesh is None:
        raise ValueError("disaggregated serving (decode_mesh=...) needs a "
                         "prefill mesh too")
    if mesh is not None and rules is None:
        rules = shd.PRESETS["serve_sp"]
    if disagg and decode_rules is None:
        decode_rules = shd.PRESETS["serve_decode"]
    dec_mesh = decode_mesh if disagg else mesh
    dec_rules = decode_rules if disagg else rules

    prefill_fn = step_lib.make_prefill_step(cfg, act_transport)
    # Under the serve_decode preset the cache is resident — decode has no
    # per-step gather to compress, so an int8 act transport there would
    # only round the whole resident cache through s8 every step (logit
    # drift, extra compute, zero wire saved). Drop to bf16 for the decode
    # half; custom decode_rules keep the caller's choice.
    dec_act = "bf16" if disagg and dec_rules is shd.PRESETS["serve_decode"] \
        else act_transport
    # validates kv_storage (and the family's eligibility for int8)
    decode_fn = step_lib.make_decode_step(cfg, total, dec_act, kv_storage)

    pre_ctx = shd.axis_rules(mesh, rules) if mesh is not None \
        else contextlib.nullcontext()
    dec_ctx = shd.axis_rules(dec_mesh, dec_rules) if dec_mesh is not None \
        else contextlib.nullcontext()

    c_abs_bf16 = transformer.abstract_cache(cfg, b, total)

    with pre_ctx:
        params_pre = params
        if mesh is not None:
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            params_pre = jax.device_put(params, p_shard)
        prefill = jax.jit(prefill_fn)
        pre_batch = {"tokens": jnp.asarray(prompts)}
        if ragged:
            pre_batch["last_pos"] = jnp.asarray(lens - 1)
        logits, cache = prefill(params_pre, pre_batch)
        cache = grow_cache(cache, c_abs_bf16)

    # ---- handoff: place the grown cache (and params) on the decode side
    with dec_ctx:
        c_shard = None
        params_dec = params_pre
        if dec_mesh is not None:
            c_axes = transformer.cache_axes(cfg, b, total)
            dst = shd.tree_shardings(c_abs_bf16, c_axes, dec_mesh, dec_rules)
            c_shard = dst
            if kv_storage != "bf16":
                c_shard = shd.tree_shardings(
                    transformer.abstract_cache(cfg, b, total,
                                               kv_storage=kv_storage),
                    transformer.cache_axes(cfg, b, total,
                                           kv_storage=kv_storage),
                    dec_mesh, dec_rules)
            if disagg:
                # the decode cluster holds its own replica of the weights
                p_shard_dec = shd.tree_shardings(
                    transformer.abstract_params(cfg),
                    transformer.param_axes(cfg), dec_mesh, dec_rules)
                params_dec = jax.device_put(params, p_shard_dec)
                cache = make_cache_mover(cfg, b, total, dec_mesh,
                                         dec_rules, cache_transfer,
                                         dst)(cache)
            else:
                # colocated: commit the grown cache to its serve placement
                cache = jax.device_put(cache, dst)
        if kv_storage != "bf16":
            quant = jax.jit(lambda c: transformer.quantize_cache(
                c, kv_storage), out_shardings=c_shard)
            cache = quant(cache)
        decode = jax.jit(decode_fn, out_shardings=(None, c_shard)) \
            if c_shard is not None else jax.jit(decode_fn)

        # first sampled token comes from prefill logits — the one batch
        # tensor that crosses from the prefill to the decode mesh
        key = jax.random.PRNGKey(seed)
        out_tokens = []
        tok = jnp.asarray(np.asarray(jnp.argmax(logits, -1),
                                     dtype=np.int32)[:, None])
        for i in range(max_new):
            out_tokens.append(np.asarray(tok))
            pos = jnp.asarray(lens + i) if ragged \
                else jnp.asarray(s0 + i, jnp.int32)
            logits, cache = decode(params_dec, cache,
                                   {"tokens": tok, "pos": pos})
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature
                                             ).astype(jnp.int32)[:, None]
            else:
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.concatenate(out_tokens, axis=1)


def supports_slot_streaming(cfg) -> bool:
    """Every family serves through slot streaming now that admission is a
    StateStore row write: attention caches admit as ``[1, total]`` cache
    slices, ring-buffer and recurrent (``row_state``) families admit
    their O(1) per-row state as a whole-row overwrite after an
    exact-length prefill."""
    return registry.capabilities(cfg).slot_stream


def _require_slot_streaming(cfg) -> None:
    registry.require(cfg, "slot_stream", "--stream slots")


def make_slot_admit_step(cfg, slots: int, total: int, transfer: str,
                         kv_storage: str,
                         block: int = collectives.ACT_BLOCK):
    """Admission step of continuous slot streaming: returns
    ``admit(cache, slice, slot) -> cache`` — a thin wrapper over
    :meth:`repro.models.registry.StateStore.admit_row`, writing one
    request's grown ``[1, total]`` bf16 state slice into row ``slot`` of
    the *running* decode state table (in its resident storage layout).
    ``slot`` is a traced scalar, so one compiled program serves every
    slot.

    ``transfer`` is the colocated wire form: ``"int8"`` routes each
    sequence-carrying leaf through ``collectives.stream_slot_int8`` and
    each O(1) row-state leaf through ``collectives.stream_row_int8``, so
    the compiled slice reshard carries s8 chunks + f32 scales — the
    program the dryrun parses for per-slot wire bytes. The two-mesh
    launcher ships the slice with ``make_cache_mover`` *before* admission
    and calls this with ``transfer="bf16"``.
    """
    if transfer not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {transfer!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")
    _require_slot_streaming(cfg)
    store = registry.state_store(cfg, slots, total, kv_storage=kv_storage)

    def admit(cache, slc, slot):
        return store.admit_row(cache, slc, slot, transfer=transfer,
                               block=block)
    return admit


def _generate_slots(cfg, params, prompts: np.ndarray, max_new: int,
                    temperature: float, seed: int,
                    prompt_lens: Optional[np.ndarray],
                    mesh, rules, act_transport: str,
                    decode_mesh, decode_rules,
                    cache_transfer: str, kv_storage: str, slots: int,
                    horizon: int = 0):
    """Continuous cross-batch disaggregation: prefill streams each
    finished request's cache slice into a RUNNING decode batch.

    The decode side holds a slot table of ``slots`` rows (the state's
    batch dim doubles as the slot dim). Each request is prefilled on its
    own — ``[1, S0]`` with a per-row last position for dense caches,
    ``[1, len_i]`` exact-length for ``row_state`` families (ring buffers
    and recurrent scans must never see pad tokens) — its grown slice is
    quantized/shipped/dequantized into a free slot
    (:func:`make_slot_admit_step`, a :class:`~repro.models.registry.\
StateStore` row write), and the slot decodes from the request's own
    position while other slots are mid-decode or still empty. A finished slot is freed and reused by the next pending
    request — admission overwrites the entire ``[1, total]`` row, so no
    state can bleed between consecutive occupants. Transfers are
    double-buffered: the next pending request's prefill + wire shipment
    is dispatched (async) at admission time, so it overlaps the decode
    steps that run before the next slot frees; the wall-clock wait the
    overlap failed to hide is recorded in ``_generate_slots.last_stats``
    (the launcher prints it).

    Returns tokens ``(B, max_new)``; greedy tokens are token-for-token
    identical to the whole-batch path (per-row attention independence —
    the property ``tests/test_serve_disagg.py`` pins on the 8-device
    mesh).
    """
    b, s0 = prompts.shape
    total = int(horizon) if horizon else s0 + max_new
    lens = np.asarray(prompt_lens, np.int32) if prompt_lens is not None \
        else np.full((b,), s0, np.int32)
    _check_prompt_lens(cfg, lens, b, s0, max_new, total, paged=False)
    # fail before any compile: quantized storage refuses recurrent
    # caches; make_slot_admit_step re-checks for direct callers
    _require_slot_streaming(cfg)
    caps = registry.capabilities(cfg)
    if cache_transfer not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {cache_transfer!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")
    n_slots = int(slots) if slots else b
    if n_slots < 1:
        raise ValueError(f"slot table needs at least one slot, got {slots}")

    disagg = decode_mesh is not None
    if disagg and mesh is None:
        raise ValueError("disaggregated serving (decode_mesh=...) needs a "
                         "prefill mesh too")
    if mesh is not None and rules is None:
        rules = shd.PRESETS["serve_sp"]
    if disagg and decode_rules is None:
        decode_rules = shd.PRESETS["serve_decode"]
    dec_mesh = decode_mesh if disagg else mesh
    dec_rules = decode_rules if disagg else rules

    prefill_fn = step_lib.make_prefill_step(cfg, act_transport)
    dec_act = "bf16" if disagg and dec_rules is shd.PRESETS["serve_decode"] \
        else act_transport
    decode_fn = step_lib.make_decode_step(cfg, total, dec_act, kv_storage)

    pre_ctx = shd.axis_rules(mesh, rules) if mesh is not None \
        else contextlib.nullcontext()
    dec_ctx = shd.axis_rules(dec_mesh, dec_rules) if dec_mesh is not None \
        else contextlib.nullcontext()

    slice_abs = transformer.abstract_cache(cfg, 1, total)
    store_abs = transformer.abstract_cache(cfg, n_slots, total,
                                           kv_storage=kv_storage)

    with pre_ctx:
        params_pre = params
        if mesh is not None:
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         mesh, rules)
            params_pre = jax.device_put(params, p_shard)
        prefill = jax.jit(prefill_fn)
        grow = jax.jit(lambda c: grow_cache(c, slice_abs))

    with dec_ctx:
        c_shard = mover = None
        params_dec = params_pre
        if dec_mesh is not None:
            c_shard = shd.tree_shardings(
                store_abs,
                transformer.cache_axes(cfg, n_slots, total,
                                       kv_storage=kv_storage),
                dec_mesh, dec_rules)
            if disagg:
                p_shard_dec = shd.tree_shardings(
                    transformer.abstract_params(cfg),
                    transformer.param_axes(cfg), dec_mesh, dec_rules)
                params_dec = jax.device_put(params, p_shard_dec)
                slice_dst = shd.tree_shardings(
                    slice_abs, transformer.cache_axes(cfg, 1, total),
                    dec_mesh, dec_rules)
                mover = make_cache_mover(cfg, 1, total, dec_mesh, dec_rules,
                                         cache_transfer, slice_dst)
        admit = jax.jit(make_slot_admit_step(
            cfg, n_slots, total,
            "bf16" if disagg else cache_transfer, kv_storage),
            out_shardings=c_shard)
        decode = jax.jit(decode_fn, out_shardings=(None, c_shard)) \
            if c_shard is not None else jax.jit(decode_fn)
        cache = jax.jit(lambda: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), store_abs),
            out_shardings=c_shard)()

    # ---- host-side slot table + double-buffered prefetch ----------------
    key = jax.random.PRNGKey(seed)
    out_tokens = [[] for _ in range(b)]
    slot_req = [-1] * n_slots          # request id per slot, -1 = free
    slot_tok = np.zeros((n_slots,), np.int32)
    slot_pos = np.zeros((n_slots,), np.int32)
    slot_keys: list = [None] * n_slots
    next_req = 0
    inflight: list = []                # at most one prefetched shipment
    stats = {"admissions": 0, "transfer_wait_s": 0.0, "decode_steps": 0}

    def start_prefetch():
        """Prefill + ship the next pending request (async dispatch): the
        wire transfer overlaps whatever decode steps run before the next
        admission — the double buffer."""
        nonlocal next_req
        if next_req >= b or inflight:
            return
        i = next_req
        next_req += 1
        with pre_ctx:
            if caps.row_state:
                # ring-buffer / recurrent state: pad tokens must never
                # enter the per-row state, so prefill the request at its
                # exact length (one compile per distinct length) instead
                # of masking a padded batch
                logits, c = prefill(params_pre, {
                    "tokens": jnp.asarray(prompts[i:i + 1, :lens[i]])})
            else:
                logits, c = prefill(params_pre, {
                    "tokens": jnp.asarray(prompts[i:i + 1]),
                    "last_pos": jnp.asarray(lens[i:i + 1] - 1)})
            slc = grow(c)
            tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
        if mover is not None:
            slc = mover(slc)
        inflight.append((i, slc, tok0))

    def emit(i, t, slot):
        out_tokens[i].append(int(t))
        if len(out_tokens[i]) >= max_new:
            slot_req[slot] = -1        # free the slot for reuse

    def admit_next(slot):
        nonlocal cache
        if not inflight:
            start_prefetch()
        i, slc, tok0 = inflight.pop(0)
        t0 = time.time()
        jax.block_until_ready(slc)     # what the overlap failed to hide
        stats["transfer_wait_s"] += time.time() - t0
        with dec_ctx:
            cache = admit(cache, slc, jnp.asarray(slot, jnp.int32))
        stats["admissions"] += 1
        slot_req[slot] = i
        slot_pos[slot] = lens[i]
        slot_tok[slot] = int(np.asarray(tok0)[0])
        slot_keys[slot] = jax.random.fold_in(key, i)
        emit(i, slot_tok[slot], slot)  # the prefill token
        start_prefetch()               # double buffer the next shipment

    start_prefetch()
    while True:
        # keep admitting until the table is full or the queue drains — a
        # slot freed AT admission (max_new == 1: the prefill token is the
        # whole request) must be refilled in the same pass, or pending
        # requests would be dropped when every slot reads free below
        admitted = True
        while admitted:
            admitted = False
            for s_ in range(n_slots):
                if slot_req[s_] < 0 and (inflight or next_req < b):
                    admit_next(s_)
                    admitted = True
        if all(r < 0 for r in slot_req):
            break                      # nothing active, nothing pending
        tok = jnp.asarray(slot_tok[:, None])
        pos = jnp.asarray(slot_pos)
        with dec_ctx:
            logits, cache = decode(params_dec, cache,
                                   {"tokens": tok, "pos": pos})
        stats["decode_steps"] += 1
        if temperature > 0:
            logits_np = np.asarray(logits, np.float32)
            nxt = np.zeros((n_slots,), np.int32)
            for s_ in range(n_slots):
                if slot_req[s_] < 0:
                    continue
                slot_keys[s_], sub = jax.random.split(slot_keys[s_])
                nxt[s_] = int(jax.random.categorical(
                    sub, jnp.asarray(logits_np[s_]) / temperature))
        else:
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s_ in range(n_slots):
            i = slot_req[s_]
            if i < 0:
                continue
            slot_tok[s_] = nxt[s_]
            slot_pos[s_] += 1
            emit(i, nxt[s_], s_)

    assert all(len(ts) == max_new for ts in out_tokens)
    _generate_slots.last_stats = stats     # launcher reporting hook
    return np.asarray(out_tokens, np.int32)


def _generate_fanin(cfg, params, prompts: np.ndarray, max_new: int,
                    temperature: float, seed: int,
                    prompt_lens: Optional[np.ndarray],
                    mesh, rules, act_transport: str,
                    decode_mesh, decode_rules,
                    cache_transfer: str, kv_storage: str, slots: int,
                    workers: int, evict: str, paged: bool, page_size: int,
                    pool_pages: int, horizon: int,
                    priorities: Optional[np.ndarray], prefill_meshes):
    """Multi-prefill-worker fan-in with slot preemption and an optional
    paged slot cache.

    ``workers`` prefill workers — each the slot streamer's prefill +
    double-buffered mover, on its own mesh when ``prefill_meshes`` gives
    one per worker — feed ONE decode slot table. Admission order is
    owned by :class:`repro.dist.fanin.AdmissionArbiter` (FIFO with
    priority classes, aging + hard promotion, per-worker in-flight
    accounting); the engine *blocks on the arbiter's chosen shipment*
    rather than admitting whichever worker finishes first, so the token
    stream is replayable under permuted worker completion order.

    Preemption is recompute-style: when the table is full and the
    pending request outranks a victim (or has hit the hard promotion
    bound), the victim's slot is freed, and the victim requeues with its
    already-emitted tokens appended to its prompt and ``max_new``
    reduced by them. Readmission prefills the extended prompt at its
    exact length — the first readmitted token comes from the prefill's
    last-position logits — so the greedy continuation is bit-identical
    to an uncontended run (the parity ``tests/test_serve_fanin.py``
    pins).

    ``paged=True`` stores the slot table as a
    :class:`repro.models.registry.PagedStateStore`: admission allocates
    and ships only the pages covering the request's live positions, a
    fresh page is allocated (host-side) whenever a slot decodes across a
    page boundary, and each decode step runs the *unchanged* dense step
    bracketed by the store's gather/scatter through the page table —
    greedy tokens bit-match the unpaged path. The page size comes from
    the tuned ``paged_attn`` registry point unless ``page_size`` pins
    it; ``pool_pages`` bounds the shared pool (0 = fully backed), and
    exhausting it is a loud error, never a stall. Long requests that an
    unpaged horizon would refuse admit here — the horizon grows to the
    next page multiple that fits the longest request.

    Greedy only: an evicted request re-prefills its emitted tokens, and
    a sampled continuation across that recompute is not replayable.
    """
    if temperature > 0:
        raise ValueError(
            "fan-in serving is greedy-only: an evicted request re-prefills "
            "its emitted tokens on readmission, and a sampled continuation "
            "across that recompute is not replayable; use temperature=0 "
            "(the single-worker paths support sampling)")
    if evict not in fanin.EVICTION_POLICIES:
        raise ValueError(f"unknown eviction policy {evict!r}; "
                         f"expected one of {fanin.EVICTION_POLICIES}")
    if workers < 1:
        raise ValueError(f"need at least one prefill worker, got {workers}")
    if cache_transfer not in collectives.CACHE_TRANSFERS:
        raise ValueError(f"unknown cache_transfer {cache_transfer!r}; "
                         f"expected one of {collectives.CACHE_TRANSFERS}")
    b, s0 = prompts.shape
    lens = np.asarray(prompt_lens, np.int32) if prompt_lens is not None \
        else np.full((b,), s0, np.int32)
    _require_slot_streaming(cfg)
    caps = registry.capabilities(cfg)
    prios = np.zeros((b,), np.int32) if priorities is None \
        else np.asarray(priorities, np.int32)
    if prios.shape != (b,):
        raise ValueError(f"priorities shape {tuple(prios.shape)} does not "
                         f"match the batch ({b},)")
    classes = int(prios.max()) + 1 if b else 1
    n_slots = int(slots) if slots else b
    if n_slots < 1:
        raise ValueError(f"slot table needs at least one slot, got {slots}")

    # ---- horizon / page sizing -----------------------------------------
    if paged:
        # the horizon never caps a paged table — it grows to the longest
        # request (that is the bugfix's "--paged admits it" arm)
        base = max(int(horizon), int((lens + max_new).max()))
        P = int(page_size) or _default_page(base)
        if P < 1:
            raise ValueError(f"page size must be >= 1, got {P}")
        total = -(-base // P) * P        # next page multiple that fits
        _check_prompt_lens(cfg, lens, b, s0, max_new, total, paged=True)
    else:
        P = 0
        total = int(horizon) if horizon else s0 + max_new
        _check_prompt_lens(cfg, lens, b, s0, max_new, total, paged=False)

    disagg = decode_mesh is not None
    if prefill_meshes is not None:
        prefill_meshes = list(prefill_meshes)
        if len(prefill_meshes) != workers:
            raise ValueError(
                f"{len(prefill_meshes)} prefill meshes for {workers} "
                f"workers: fan-in needs one mesh per worker (or none)")
        if mesh is None:
            mesh = prefill_meshes[0]
    else:
        prefill_meshes = [mesh] * workers
    if disagg and mesh is None:
        raise ValueError("disaggregated serving (decode_mesh=...) needs a "
                         "prefill mesh too")
    if mesh is not None and rules is None:
        rules = shd.PRESETS["serve_sp"]
    if disagg and decode_rules is None:
        decode_rules = shd.PRESETS["serve_decode"]
    dec_mesh = decode_mesh if disagg else mesh
    dec_rules = decode_rules if disagg else rules

    prefill_fn = step_lib.make_prefill_step(cfg, act_transport)
    dec_act = "bf16" if disagg and dec_rules is shd.PRESETS["serve_decode"] \
        else act_transport
    decode_fn = step_lib.make_decode_step(cfg, total, dec_act, kv_storage)

    pre_ctx = [shd.axis_rules(m, rules) if m is not None
               else contextlib.nullcontext() for m in prefill_meshes]
    dec_ctx = shd.axis_rules(dec_mesh, dec_rules) if dec_mesh is not None \
        else contextlib.nullcontext()

    # ---- params: one placement per distinct prefill mesh ----------------
    params_pre = [params] * workers
    placed = {}
    for w, m in enumerate(prefill_meshes):
        if m is None:
            continue
        if id(m) not in placed:
            p_shard = shd.tree_shardings(transformer.abstract_params(cfg),
                                         transformer.param_axes(cfg),
                                         m, rules)
            placed[id(m)] = jax.device_put(params, p_shard)
        params_pre[w] = placed[id(m)]
    # One jit per DISTINCT worker mesh: ``constrain`` bakes the trace-time
    # mesh into the jaxpr and jit reuses traces by aval alone, so a shared
    # jit would replay worker 0's sharding constraints on worker 1's
    # devices (incompatible-devices error on the first cross-worker call).
    _prefill_jits = {}

    def prefill_for(w):
        k = id(prefill_meshes[w])
        if k not in _prefill_jits:
            # a DISTINCT callable per mesh, not just a distinct jit
            # wrapper: pjit's trace cache is keyed on the wrapped
            # function object, so jitting the same fn twice would still
            # share the first worker's jaxpr
            _prefill_jits[k] = jax.jit(
                lambda p, batch, _f=prefill_fn: _f(p, batch))
        return _prefill_jits[k]

    # per-slice-width jits: fit (pre side) and mover (cross-mesh); paged
    # admissions ship ceil(len / page) pages, so the width varies
    fit_jits, mover_jits = {}, {}

    def fit(width):
        if width not in fit_jits:
            abs_w = transformer.abstract_cache(cfg, 1, width)
            fit_jits[width] = jax.jit(lambda c, a=abs_w: fit_cache(c, a))
        return fit_jits[width]

    def mover(width):
        if width not in mover_jits:
            abs_w = transformer.abstract_cache(cfg, 1, width)
            dst = shd.tree_shardings(abs_w,
                                     transformer.cache_axes(cfg, 1, width),
                                     dec_mesh, dec_rules)
            mover_jits[width] = make_cache_mover(
                cfg, 1, width, dec_mesh, dec_rules, cache_transfer, dst)
        return mover_jits[width]

    # ---- decode-side programs: slot table (dense or paged) --------------
    with dec_ctx:
        c_shard = None
        params_dec = params_pre[0]
        if disagg:
            p_shard_dec = shd.tree_shardings(
                transformer.abstract_params(cfg),
                transformer.param_axes(cfg), dec_mesh, dec_rules)
            params_dec = jax.device_put(params, p_shard_dec)
        admit_transfer = "bf16" if disagg else cache_transfer
        if paged:
            store = registry.paged_state_store(
                cfg, n_slots, total, kv_storage=kv_storage, page=P,
                pool_pages=int(pool_pages))
            store_abs = store.abstract_state()
            if dec_mesh is not None:
                c_shard = shd.tree_shardings(store_abs, store.state_axes(),
                                             dec_mesh, dec_rules)

            def admit_fn(cache, slc, page_idx):
                return store.admit_pages(cache, slc, page_idx,
                                         transfer=admit_transfer)

            def paged_step(p, pool, pt, batch):
                dense = store.gather_dense(pool, pt)
                logits, dense = decode_fn(p, dense, batch)
                return logits, store.scatter_dense(pool, dense, pt)

            admit = jax.jit(admit_fn, out_shardings=c_shard)
            decode = jax.jit(paged_step, out_shardings=(None, c_shard)) \
                if c_shard is not None else jax.jit(paged_step)
        else:
            store = registry.state_store(cfg, n_slots, total,
                                         kv_storage=kv_storage)
            store_abs = transformer.abstract_cache(cfg, n_slots, total,
                                                   kv_storage=kv_storage)
            if dec_mesh is not None:
                c_shard = shd.tree_shardings(
                    store_abs,
                    transformer.cache_axes(cfg, n_slots, total,
                                           kv_storage=kv_storage),
                    dec_mesh, dec_rules)
            admit = jax.jit(make_slot_admit_step(
                cfg, n_slots, total, admit_transfer, kv_storage),
                out_shardings=c_shard)
            decode = jax.jit(decode_fn, out_shardings=(None, c_shard)) \
                if c_shard is not None else jax.jit(decode_fn)
        cache = jax.jit(lambda: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), store_abs),
            out_shardings=c_shard)()

    # ---- host state: queue, slot table, page table ----------------------
    arb = fanin.AdmissionArbiter(workers=workers, classes=classes)
    base_prompts = [np.asarray(prompts[i, :lens[i]], np.int32).copy()
                    for i in range(b)]
    for i in range(b):
        arb.submit(fanin.Request(rid=i, prompt=base_prompts[i],
                                 max_new=int(max_new),
                                 priority=int(prios[i])))
    out_tokens = [[] for _ in range(b)]
    remaining = np.full((b,), max_new, np.int64)
    slot_occ: list = [None] * n_slots           # fanin.Occupant or None
    slot_reqobj: list = [None] * n_slots        # fanin.Request or None
    slot_tok = np.zeros((n_slots,), np.int32)
    slot_pos = np.zeros((n_slots,), np.int32)
    shipments = {}                              # rid -> (slc, tok0, length)
    pt = store.init_page_table() if paged else None
    free_pages = deque(range(store.n_pool)) if paged else None
    stats = {"admissions": 0, "evictions": 0, "requeues": 0,
             "decode_steps": 0, "transfer_wait_s": 0.0,
             "max_wait_passes": 0, "peak_live_pages": 0}

    def alloc_page() -> int:
        if not free_pages:
            raise RuntimeError(
                f"paged pool exhausted: all {store.n_pool} pages of the "
                f"{n_slots}-slot table are live; raise --pool-pages "
                f"(0 = fully backed: slots x pages-per-row = "
                f"{n_slots * store.pages_per_row}) or lower --slots")
        p = free_pages.popleft()
        stats["peak_live_pages"] = max(stats["peak_live_pages"],
                                       store.n_pool - len(free_pages))
        return p

    def free_row(s):
        if paged:
            for pg in np.nonzero(pt[s] >= 0)[0]:
                free_pages.append(int(pt[s, pg]))
            pt[s, :] = -1
        slot_occ[s] = None
        slot_reqobj[s] = None

    def ensure_page(s, pos):
        """Allocate the page holding ``pos`` before the slot writes it."""
        pg = pos // P
        if pg >= store.pages_per_row:
            raise RuntimeError(
                f"slot {s} at position {pos} is past the {total}-position "
                f"paged horizon — engine accounting bug")
        if pt[s, pg] < 0:
            pt[s, pg] = alloc_page()

    def dispatch(req):
        """Prefill + ship one assigned request on its worker (async): the
        wire transfer overlaps decode steps until the arbiter admits it."""
        plen = int(req.prompt.shape[0])
        w = req.worker
        with pre_ctx[w]:
            if req.evictions == 0 and not caps.row_state and plen <= s0:
                # fresh admission: padded [1, S0] prefill with a last
                # position — the same program for every fresh request
                toks = np.zeros((1, s0), np.int32)
                toks[0, :plen] = req.prompt
                logits, c = prefill_for(w)(params_pre[w], {
                    "tokens": jnp.asarray(toks),
                    "last_pos": jnp.asarray([plen - 1])})
            else:
                # readmission (or row_state): exact-length prefill of the
                # extended prompt — pad tokens must never enter row state,
                # and the recompute must replay the emitted continuation
                logits, c = prefill_for(w)(params_pre[w], {
                    "tokens": jnp.asarray(req.prompt[None, :])})
            width = -(-plen // P) * P if paged else total
            slc = fit(width)(c)
            tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
        if disagg:
            slc = mover(width)(slc)
        shipments[req.rid] = (slc, tok0, plen)

    def emit(i, t, s):
        out_tokens[i].append(int(t))
        remaining[i] -= 1
        if remaining[i] <= 0:
            free_row(s)

    def evict_slot(s):
        req = slot_reqobj[s]
        arb.evicted(req)
        # recompute preemption: requeue with the emitted tokens appended,
        # budget reduced by them; aging restarts for the new occupancy
        req.prompt = np.concatenate(
            [base_prompts[req.rid],
             np.asarray(out_tokens[req.rid], np.int32)])
        req.max_new = int(remaining[req.rid])
        free_row(s)
        arb.submit(req, requeue=True)
        stats["evictions"] += 1
        stats["requeues"] += 1

    def admit_into(s, req):
        nonlocal cache
        slc, tok0, plen = shipments.pop(req.rid)
        t0 = time.time()
        jax.block_until_ready(slc)   # the arbiter's choice, NOT first-done
        stats["transfer_wait_s"] += time.time() - t0
        occ = arb.admit(req)
        stats["max_wait_passes"] = max(stats["max_wait_passes"], req.skips)
        with dec_ctx:
            if paged:
                n_ship = -(-plen // P)
                idx = np.asarray([alloc_page() for _ in range(n_ship)],
                                 np.int32)
                pt[s, :n_ship] = idx
                cache = admit(cache, slc, jnp.asarray(idx))
            else:
                cache = admit(cache, slc, jnp.asarray(s, jnp.int32))
        stats["admissions"] += 1
        slot_occ[s] = occ
        slot_reqobj[s] = req
        slot_pos[s] = plen
        slot_tok[s] = int(np.asarray(tok0)[0])
        emit(req.rid, slot_tok[s], s)           # the prefill token

    def try_admissions():
        while True:
            req = arb.next_admission()
            if req is None:
                return
            s = next((i for i in range(n_slots) if slot_occ[i] is None),
                     None)
            if s is None:
                s = arb.pick_victim(slot_occ, evict, req)
                if s is None:
                    return              # no justified victim: age in queue
                evict_slot(s)
            admit_into(s, req)

    # ---- main loop: assign -> admit -> age -> decode --------------------
    passes = 0
    limit = 1000 + 20 * b * (max_new + n_slots + arb.promotion_cycles)
    while True:
        passes += 1
        if passes > limit:
            raise RuntimeError(
                f"fan-in engine made no progress in {limit} passes "
                f"(queue={len(arb.queue)}, "
                f"occupied={sum(o is not None for o in slot_occ)})")
        for req in arb.assign():
            dispatch(req)
        try_admissions()
        arb.age()
        if all(o is None for o in slot_occ):
            if not arb.queue:
                break
            continue
        if paged:
            for s in range(n_slots):
                if slot_occ[s] is not None:
                    ensure_page(s, int(slot_pos[s]))
        tok = jnp.asarray(slot_tok[:, None])
        pos = jnp.asarray(slot_pos)
        with dec_ctx:
            if paged:
                logits, cache = decode(params_dec, cache, jnp.asarray(pt),
                                       {"tokens": tok, "pos": pos})
            else:
                logits, cache = decode(params_dec, cache,
                                       {"tokens": tok, "pos": pos})
        stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        for s in range(n_slots):
            if slot_occ[s] is None:
                continue
            slot_tok[s] = int(nxt[s])
            slot_pos[s] += 1
            emit(slot_reqobj[s].rid, int(nxt[s]), s)

    bad = [i for i in range(b) if len(out_tokens[i]) != max_new]
    if bad:
        raise RuntimeError(f"fan-in engine dropped requests {bad}: "
                           f"emitted {[len(out_tokens[i]) for i in bad]} "
                           f"of {max_new} tokens")
    if paged:
        stats["page"] = P
        stats["hbm_bytes_per_slot"] = (stats["peak_live_pages"]
                                       * store.page_bytes()) // n_slots
        dense = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in store.dense_abstract_state().values())
        stats["dense_hbm_bytes_per_slot"] = dense // n_slots
    _generate_fanin.last_stats = stats          # launcher reporting hook
    return np.asarray(out_tokens, np.int32)


def _pick_tp(n_devices: int, cfg) -> int:
    """Largest model-parallel degree (<= 2) the device count and head
    counts admit — the smoke default; override with --tp."""
    for tp in (2, 1):
        if n_devices % tp == 0 and cfg.n_heads % tp == 0:
            return tp
    return 1


def make_disagg_meshes(cfg, tp_prefill: int = 0, tp_decode: int = 0):
    """Split the local devices into a prefill mesh and a decode mesh.

    With >= 2 devices the halves are disjoint — two real clusters, the
    cache handoff is a genuine cross-mesh transfer. A single device serves
    both roles (degenerate (1, 1) meshes), so the smoke path runs
    anywhere. Each half keeps a (data, model) layout; ``tp_*=0``
    auto-picks the model degree per half.
    """
    devs = jax.devices()
    n = len(devs)
    pre, dec = (devs[:n // 2], devs[n // 2:]) if n >= 2 else (devs, devs)

    def mk(ds, tp):
        tp = tp or _pick_tp(len(ds), cfg)
        if len(ds) % tp != 0:
            raise ValueError(
                f"model-parallel degree {tp} does not divide the "
                f"{len(ds)}-device mesh half: disaggregated serving gives "
                f"each role {len(ds)} of the {n} devices, so --tp must "
                f"divide that")
        arr = np.array(ds).reshape(len(ds) // tp, tp)
        return jax.sharding.Mesh(arr, ("data", "model"))
    return mk(pre, tp_prefill), mk(dec, tp_decode)


def make_fanin_meshes(cfg, workers: int, tp_prefill: int = 0,
                      tp_decode: int = 0):
    """Split the local devices into ``workers`` prefill-worker meshes plus
    one decode mesh.

    The decode half mirrors :func:`make_disagg_meshes`; the prefill half
    is divided evenly among the workers (each an independent
    ``(data, model)`` mesh — N real prefill clusters) when its device
    count allows, and shared by every worker otherwise (the workers are
    then concurrency lanes on one mesh — degenerate, but it runs
    anywhere and still exercises the admission arbiter). Returns
    ``(prefill_meshes, decode_mesh)`` with ``len(prefill_meshes) ==
    workers``.
    """
    if workers < 1:
        raise ValueError(f"need at least one prefill worker, got {workers}")
    devs = jax.devices()
    n = len(devs)
    pre, dec = (devs[:n // 2], devs[n // 2:]) if n >= 2 else (devs, devs)
    if len(pre) >= workers and len(pre) % workers == 0:
        chunk = len(pre) // workers
        groups = [pre[w * chunk:(w + 1) * chunk] for w in range(workers)]
    else:
        groups = [list(pre)] * workers

    def mk(ds, tp):
        tp = tp or _pick_tp(len(ds), cfg)
        if len(ds) % tp != 0:
            raise ValueError(
                f"model-parallel degree {tp} does not divide the "
                f"{len(ds)}-device mesh: fan-in gives each of the "
                f"{workers} prefill workers {len(groups[0])} and decode "
                f"{len(dec)} of the {n} devices, so --tp must divide "
                f"those")
        arr = np.array(ds).reshape(len(ds) // tp, tp)
        return jax.sharding.Mesh(arr, ("data", "model"))
    return [mk(g, tp_prefill) for g in groups], mk(dec, tp_decode)


def disagg_decode_report(cfg, batch: int, seq_len: int, mesh,
                         ici_bw: float = 50e9, hbm_bw: float = 819e9,
                         transfers=collectives.CACHE_TRANSFERS,
                         storages=collectives.KV_STORAGES,
                         blocks=(collectives.ACT_BLOCK,)):
    """Compile the disaggregated-decode design space on one mesh and
    report every cache_transfer x kv_storage (x stream block) combination.

    Per combination ``"<transfer>x<storage>"``: ``transfer_s`` (the
    serve_sp -> serve_decode cache reshard's wire, HLO-parsed from the
    compiled transfer program), ``decode_step_s`` (the decode step's
    per-token wire under the storage arm), their sum ``collective_s``,
    ``cache_resident_bytes_per_device`` (what the decode mesh's HBM
    actually holds — the storage arm's rent), and
    ``slot_stream_overlap_frac``: the fraction of a *per-slot* transfer
    (one request's ``[1, seq]`` slice, HLO-parsed from the compiled slot
    admission program — ``rep["slot_stream"]``) a double-buffered
    admission hides behind decode steps, modeling the steady state where
    the slot table readmits one of its ``batch`` slots every
    ``seq_len/batch`` decode steps. Extra ``blocks`` sweep the stream's
    quantization block size (``rep["block_sweep"]``; f32 scales per
    block, so smaller blocks buy fidelity with wire), and
    ``rep["tuned"]`` is the ``repro.core.autotune.tune_design`` hillclimb
    over transfer x storage x block minimizing the combo's modeled cost:
    wire ``collective_s`` plus the per-token HBM read of the resident
    cache (``cache_resident_bytes / hbm_bw`` — what the storage arm
    actually buys back). Storage arms a family does not support (recurrent
    caches) are skipped and named in ``"unsupported_storage"``. Used by
    ``repro.launch.dryrun`` for decode cells and exercised directly by
    the disagg mesh tests.
    """
    from repro.core import autotune
    from repro.launch import analysis

    transfers = tuple(transfers)
    storages = tuple(storages)
    blocks = tuple(blocks)
    pre_rules = shd.PRESETS["serve_sp"]
    dec_rules = shd.PRESETS["serve_decode"]
    c_abs = transformer.abstract_cache(cfg, batch, seq_len)
    c_axes = transformer.cache_axes(cfg, batch, seq_len)
    pre_shard = shd.tree_shardings(c_abs, c_axes, mesh, pre_rules)
    dec_shard = shd.tree_shardings(c_abs, c_axes, mesh, dec_rules)
    p_abs = transformer.abstract_params(cfg)
    p_shard = shd.tree_shardings(p_abs, transformer.param_axes(cfg),
                                 mesh, dec_rules)
    slice_abs = transformer.abstract_cache(cfg, 1, seq_len)
    slice_axes = transformer.cache_axes(cfg, 1, seq_len)
    slice_pre = shd.tree_shardings(slice_abs, slice_axes, mesh, pre_rules)
    slot_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    # whole-batch transfer + per-slot admission wire, per (transfer, block)
    # — the bf16 arm ignores the block, so it compiles once. Every leg a
    # family refuses is recorded in rep["skipped"] (flag -> the uniform
    # capability refusal), never silently omitted: the dryrun surfaces the
    # list in its reports, so a family whose metrics are absent from a
    # BENCH_roofline artifact names itself there.
    skipped = {}
    slot_ok = supports_slot_streaming(cfg)
    if not slot_ok:
        try:
            _require_slot_streaming(cfg)
        except NotImplementedError as e:
            skipped["--stream slots"] = str(e)
    t_coll, slot_coll = {}, {}
    for t in transfers:
        for blk in (blocks if t == "int8" else blocks[:1]):
            fn = make_cache_transfer_step(cfg, batch, seq_len, t, block=blk)
            with shd.axis_rules(mesh, dec_rules):
                hlo = jax.jit(fn, in_shardings=(pre_shard,),
                              out_shardings=dec_shard
                              ).lower(c_abs).compile().as_text()
            t_coll[(t, blk)] = analysis.hlo_collective_bytes(hlo)
            if not slot_ok:
                continue
            admit = make_slot_admit_step(cfg, batch, seq_len, t, "bf16",
                                         block=blk)
            with shd.axis_rules(mesh, dec_rules):
                hlo = jax.jit(
                    admit, in_shardings=(dec_shard, slice_pre, slot_sh),
                    out_shardings=dec_shard
                ).lower(c_abs, slice_abs,
                        jax.ShapeDtypeStruct((), jnp.int32)
                        ).compile().as_text()
            slot_coll[(t, blk)] = analysis.hlo_collective_bytes(hlo)

    def device_bytes(abs_tree, axes_tree):
        tot = 0.0
        for leaf, la in zip(jax.tree.leaves(abs_tree),
                            jax.tree.structure(abs_tree
                                               ).flatten_up_to(axes_tree)):
            spec = shd.resolve_spec(leaf.shape, tuple(la), mesh, dec_rules)
            shards = shd.spec_shard_count(spec, mesh)
            tot += float(np.prod(leaf.shape)) * leaf.dtype.itemsize / shards
        return int(tot)

    decodes, cache_bytes, unsupported = {}, {}, []
    batch_abs = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                 "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    for s in storages:
        try:
            fn = step_lib.make_decode_step(cfg, seq_len, "bf16", s)
        except NotImplementedError as e:
            unsupported.append(s)
            skipped[f"kv_storage={s!r}"] = str(e)
            continue
        cs_abs = transformer.abstract_cache(cfg, batch, seq_len,
                                            kv_storage=s)
        cs_axes = transformer.cache_axes(cfg, batch, seq_len, kv_storage=s)
        cs_shard = shd.tree_shardings(cs_abs, cs_axes, mesh, dec_rules)
        with shd.axis_rules(mesh, dec_rules):
            hlo = jax.jit(fn, in_shardings=(p_shard, cs_shard, None),
                          out_shardings=(None, cs_shard)
                          ).lower(p_abs, cs_abs, batch_abs
                                  ).compile().as_text()
        decodes[s] = analysis.hlo_collective_bytes(hlo)
        cache_bytes[s] = device_bytes(cs_abs, cs_axes)

    # steady-state decode budget per admission: all batch slots serving
    # ~seq_len-token requests readmit one slot every seq_len/batch steps
    hide_steps = max(1, seq_len // max(1, batch))
    blk0 = blocks[0]

    def _tb(t, blk):
        return t_coll[(t, blk if t == "int8" else blk0)]

    def _sb(t, blk):
        return slot_coll[(t, blk if t == "int8" else blk0)]

    cells = {}
    for t in transfers:
        tcoll = _tb(t, blk0)
        for s, dcoll in decodes.items():
            tw = float(tcoll["total_wire_bytes_bf16eq"])
            dw = float(dcoll["total_wire_bytes_bf16eq"])
            cells[f"{t}x{s}"] = {
                "transfer_s": tw / ici_bw,
                "decode_step_s": dw / ici_bw,
                "collective_s": (tw + dw) / ici_bw,
                "transfer_wire_bytes_bf16eq": int(tw),
                "transfer_wire_bytes_bf16eq_s8":
                    int(tcoll["total_wire_bytes_bf16eq_s8"]),
                "decode_wire_bytes_bf16eq": int(dw),
                "cache_resident_bytes_per_device": cache_bytes[s],
            }
            if slot_ok:
                sw = float(_sb(t, blk0)["total_wire_bytes_bf16eq"])
                slot_s = sw / ici_bw
                hidden = min(slot_s, hide_steps * dw / ici_bw)
                cells[f"{t}x{s}"]["slot_stream_overlap_frac"] = \
                    1.0 if sw == 0 else hidden / slot_s

    slot_stream = {}
    for t in (transfers if slot_ok else ()):
        sc = _sb(t, blk0)
        slot_stream[t] = {
            "wire_bytes_bf16eq": int(sc["total_wire_bytes_bf16eq"]),
            "wire_bytes_bf16eq_s8":
                int(sc["total_wire_bytes_bf16eq_s8"]),
            "transfer_s": float(sc["total_wire_bytes_bf16eq"]) / ici_bw,
            "hide_steps": hide_steps,
        }

    block_sweep = {
        t: {int(blk): {
            "transfer_wire_bytes_bf16eq":
                int(_tb(t, blk)["total_wire_bytes_bf16eq"]),
            **({"slot_wire_bytes_bf16eq":
                int(_sb(t, blk)["total_wire_bytes_bf16eq"])}
               if slot_ok else {}),
        } for blk in (blocks if t == "int8" else blocks[:1])}
        for t in transfers}

    def objective(point):
        # wire (one transfer + one decode step) + the decode step's HBM
        # read of the resident cache — the term the storage arm halves
        tw = float(_tb(point["cache_transfer"],
                       point["block"])["total_wire_bytes_bf16eq"])
        s = point["kv_storage"]
        dw = float(decodes[s]["total_wire_bytes_bf16eq"])
        return (tw + dw) / ici_bw + cache_bytes[s] / hbm_bw

    tuned = None
    if decodes:
        res = autotune.tune_design(objective, {
            "cache_transfer": transfers,
            "kv_storage": tuple(decodes),
            "block": blocks,
        })
        tuned = {"point": res.best_point,
                 "collective_s": res.best_objective,
                 "evaluations": res.evaluations}

    return {"cells": cells, "unsupported_storage": unsupported,
            "skipped": skipped,
            "slot_stream": slot_stream, "block_sweep": block_sweep,
            "hide_steps": hide_steps, "tuned": tuned}


def fanin_report(cfg, batch: int, seq_len: int, *, workers: int = 2,
                 slots: int = 0, classes: int = 2, evict: str = "priority",
                 max_new: int = 0, decode_step_s: float = 0.0,
                 transfer_s: float = 0.0, page: int = 0,
                 kv_storage: str = "bf16"):
    """Deterministic fan-in roofline: drive the REAL
    :class:`repro.dist.fanin.AdmissionArbiter` through a contended
    serving trace and price the outcome with the disagg report's
    per-step costs. No wall clock, no jax — the same inputs always
    produce the same report (the determinism ``tests/test_serve_fanin.py``
    pins), so the keys gate in ``scripts/bench_diff.py``.

    ``batch`` requests with a seeded mixed-length spread and round-robin
    priority classes contend for a ``slots``-row table (default
    ``batch // 2`` — contention by construction) fed by ``workers``
    prefill workers; each simulated cycle is one decode step of cost
    ``decode_step_s``, and a dispatched prefill+transfer costs
    ``transfer_s``, double-buffered behind the queue wait. Reported
    (all flattened into decode dryrun cells' roofline):

    * ``fanin_admission_wait_s`` — mean per-admission latency: queue
      wait (arbiter passes lost x decode step) plus the transfer time
      the overlap failed to hide;
    * ``fanin_evictions`` — preemptions the policy performed (each costs
      a re-prefill of the extended prompt);
    * ``paged_hbm_bytes_per_slot`` vs ``slot_hbm_bytes_per_slot`` — the
      paged table's live-page resident rent per slot against the dense
      pad-to-horizon baseline (only for families with the ``paged``
      capability; refusals land in ``skipped`` like every other gated
      leg).
    """
    max_new = int(max_new) or max(1, seq_len // 8)
    n_slots = int(slots) or max(1, batch // 2)
    rng = np.random.RandomState(0)
    lens = rng.randint(max(1, seq_len // 4), seq_len + 1,
                       size=(batch,)).astype(np.int64)

    arb = fanin.AdmissionArbiter(workers=workers, classes=classes)
    reqs = [fanin.Request(rid=i, prompt=np.zeros((int(lens[i]),), np.int32),
                          max_new=max_new, priority=int(i % classes))
            for i in range(batch)]
    for r in reqs:
        arb.submit(r)
    remaining = {r.rid: max_new for r in reqs}
    emitted = {r.rid: 0 for r in reqs}
    occ: list = [None] * n_slots
    occ_req: list = [None] * n_slots
    wait_s: list = []
    cycles = 0
    limit = 1000 + 20 * batch * (max_new + n_slots + arb.promotion_cycles)

    def free_row(s):
        occ[s] = None
        occ_req[s] = None

    while True:
        arb.assign()
        while True:
            req = arb.next_admission()
            if req is None:
                break
            s = next((i for i in range(n_slots) if occ[i] is None), None)
            if s is None:
                s = arb.pick_victim(occ, evict, req)
                if s is None:
                    break
                victim = occ_req[s]
                arb.evicted(victim)
                victim.prompt = np.zeros(
                    (int(lens[victim.rid]) + emitted[victim.rid],),
                    np.int32)
                victim.max_new = remaining[victim.rid]
                free_row(s)
                arb.submit(victim, requeue=True)
            queue_wait = req.skips * decode_step_s
            wait_s.append(queue_wait + max(0.0, transfer_s - queue_wait))
            o = arb.admit(req)
            occ[s] = o
            occ_req[s] = req
            emitted[req.rid] += 1       # the prefill token
            remaining[req.rid] -= 1
            if remaining[req.rid] <= 0:
                free_row(s)
        arb.age()
        if all(o_ is None for o_ in occ):
            if not arb.queue:
                break
            continue
        cycles += 1                     # one decode step over the table
        for s in range(n_slots):
            r = occ_req[s]
            if r is None:
                continue
            emitted[r.rid] += 1
            remaining[r.rid] -= 1
            if remaining[r.rid] <= 0:
                free_row(s)
        if cycles > limit:
            raise RuntimeError("fan-in report simulation made no progress")

    rep = {"workers": workers, "slots": n_slots, "classes": classes,
           "evict": evict, "decode_cycles": cycles,
           "fanin_admission_wait_s":
               float(np.mean(wait_s)) if wait_s else 0.0,
           "fanin_evictions": int(arb.stats["evictions"]),
           "max_wait_passes": int(arb.stats["max_wait"]),
           "skipped": {}}

    caps = registry.capabilities(cfg)
    if caps.paged:
        base = seq_len + max_new
        P = int(page) or _default_page(base)
        total = -(-base // P) * P
        store = registry.paged_state_store(cfg, n_slots, total,
                                           kv_storage=kv_storage, page=P)
        per_pos = store.page_bytes() / P
        live = np.minimum(lens + max_new, total)
        paged_bytes = float(np.mean(-(-live // P) * P * per_pos))
        dense = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                    for l in store.dense_abstract_state().values())
        rep["page"] = P
        rep["paged_hbm_bytes_per_slot"] = paged_bytes
        rep["slot_hbm_bytes_per_slot"] = float(dense / n_slots)
    else:
        try:
            registry.require(cfg, "paged", "--paged")
        except NotImplementedError as e:
            rep["skipped"]["--paged"] = str(e)
    return rep


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--full", action="store_true",
                    help="serve the published config instead of the "
                         "reduced smoke config (the default)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--tp", type=int, default=0,
                    help="model-parallel degree (0 = auto)")
    ap.add_argument("--preset", default="serve_sp",
                    choices=sorted(shd.PRESETS))
    ap.add_argument("--act-transport", default="bf16",
                    choices=list(step_lib.ACT_TRANSPORTS))
    ap.add_argument("--ragged", action="store_true",
                    help="serve a mixed-length batch (continuous batching)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregate: prefill and decode on separate "
                         "meshes (half the devices each), the cache handed "
                         "off between them")
    ap.add_argument("--cache-transfer", default="bf16",
                    choices=list(step_lib.CACHE_TRANSFERS),
                    help="wire format of the disagg prefill->decode cache "
                         "handoff")
    ap.add_argument("--kv-storage", default="bf16",
                    choices=list(step_lib.KV_STORAGES),
                    help="decode-resident cache dtype (int8: s8 + scales, "
                         "f8: scale-free e4m3 — both ~halve cache HBM; "
                         "attention dequantizes/upcasts per block at read "
                         "time)")
    ap.add_argument("--stream", default="batch", choices=list(STREAMS),
                    help="handoff granularity: 'batch' prefills the whole "
                         "batch then decodes it; 'slots' streams each "
                         "request's cache slice into a running decode "
                         "batch via slot admission, transfers "
                         "double-buffered behind decode steps")
    ap.add_argument("--slots", type=int, default=0,
                    help="slot-table size for --stream slots (0 = one "
                         "slot per request; smaller forces slot reuse)")
    ap.add_argument("--workers", type=int, default=1,
                    help="prefill fan-in: N independent prefill workers "
                         "feeding one decode slot table through the "
                         "admission arbiter (>1, or --paged, routes "
                         "serving through the fan-in engine; greedy only)")
    ap.add_argument("--evict", default="oldest",
                    choices=list(fanin.EVICTION_POLICIES),
                    help="slot preemption policy when the table is full "
                         "and a pending request outranks an occupant (or "
                         "hit the starvation promotion bound): the victim "
                         "requeues with its emitted tokens and is "
                         "re-prefilled on readmission (recompute "
                         "preemption)")
    ap.add_argument("--paged", action="store_true",
                    help="paged slot cache: slot rows are lists of "
                         "fixed-size pages in a shared pool with a "
                         "per-slot page table; admission ships only live "
                         "pages, pages allocate on demand, and requests "
                         "the unpaged horizon would refuse admit")
    ap.add_argument("--page-size", type=int, default=0,
                    help="positions per page for --paged (0 = the tuned "
                         "paged_attn registry point, default 256)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="page-pool size backing the paged table (0 = "
                         "fully backed: slots x pages-per-row); "
                         "exhausting it is a loud error, never a stall")
    ap.add_argument("--horizon", type=int, default=0,
                    help="decode horizon in positions (0 = prompt-len + "
                         "max-new); an unpaged request that cannot fit "
                         "is refused loudly, never silently truncated — "
                         "--paged admits it instead")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="admission priority classes for the fan-in "
                         "arbiter, round-robin assigned to the smoke "
                         "batch (0 = most urgent; with >1, --evict "
                         "priority preempts lower classes)")
    return ap


def resolve_config(args):
    """--full lowers the published config; the default is the smoke
    config (same family and code paths, CPU-runnable dims)."""
    return get_config(args.arch) if args.full else smoke_config(args.arch)


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    cfg = resolve_config(args)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode serving")

    fan_in = args.workers > 1 or args.paged
    prefill_meshes = None
    decode_mesh = decode_rules = None
    if args.disagg:
        if fan_in:
            prefill_meshes, decode_mesh = make_fanin_meshes(
                cfg, max(1, args.workers), args.tp, args.tp)
            mesh = prefill_meshes[0]
        else:
            mesh, decode_mesh = make_disagg_meshes(cfg, args.tp, args.tp)
        rules = shd.PRESETS[args.preset]
        decode_rules = shd.PRESETS["serve_decode"]
    else:
        tp = args.tp or _pick_tp(jax.device_count(), cfg)
        mesh = make_local_mesh(model_parallel=tp)
        rules = shd.PRESETS[args.preset]

    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab,
                          size=(args.batch, args.prompt_len)).astype(np.int32)
    lens = None
    if args.ragged:
        lens = rng.randint(max(1, args.prompt_len // 2), args.prompt_len + 1,
                           size=(args.batch,)).astype(np.int32)

    prios = None
    if args.priority_classes > 1:
        prios = (np.arange(args.batch)
                 % args.priority_classes).astype(np.int32)

    t0 = time.time()
    out = generate(cfg, params, prompts, max_new=args.max_new,
                   temperature=args.temperature, prompt_lens=lens,
                   mesh=mesh, rules=rules, act_transport=args.act_transport,
                   decode_mesh=decode_mesh, decode_rules=decode_rules,
                   cache_transfer=args.cache_transfer,
                   kv_storage=args.kv_storage,
                   stream=args.stream, slots=args.slots,
                   workers=args.workers, evict=args.evict,
                   paged=args.paged, page_size=args.page_size,
                   pool_pages=args.pool_pages, horizon=args.horizon,
                   priorities=prios, prefill_meshes=prefill_meshes)
    dt = time.time() - t0
    n_tok = out.size
    mesh_desc = dict(zip(mesh.axis_names, mesh.devices.shape))
    if decode_mesh is not None:
        mesh_desc = {"prefill": dict(zip(mesh.axis_names,
                                         mesh.devices.shape)),
                     "decode": dict(zip(decode_mesh.axis_names,
                                        decode_mesh.devices.shape))}
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} new={args.max_new} "
          f"mesh={mesh_desc} "
          f"preset={args.preset} act_transport={args.act_transport} "
          f"disagg={args.disagg} cache_transfer={args.cache_transfer} "
          f"kv_storage={args.kv_storage} stream={args.stream}"
          + (f" lens={lens.tolist()}" if lens is not None else ""))
    print(f"[serve] generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    if fan_in:
        st = _generate_fanin.last_stats
        print(f"[serve] fan-in: workers={args.workers} evict={args.evict} "
              f"admissions={st['admissions']} evictions={st['evictions']} "
              f"requeues={st['requeues']} decode_steps={st['decode_steps']} "
              f"transfer_wait_s={st['transfer_wait_s']:.3f} "
              f"max_wait_passes={st['max_wait_passes']}")
        if args.paged:
            print(f"[serve] paged: page={st['page']} "
                  f"peak_live_pages={st['peak_live_pages']} "
                  f"hbm_bytes_per_slot={st['hbm_bytes_per_slot']} "
                  f"(dense pad-to-horizon "
                  f"{st['dense_hbm_bytes_per_slot']})")
    elif args.stream == "slots":
        st = _generate_slots.last_stats
        print(f"[serve] slot stream: admissions={st['admissions']} "
              f"decode_steps={st['decode_steps']} "
              f"transfer_wait_s={st['transfer_wait_s']:.3f} "
              "(wire time the double buffer failed to hide behind decode)")
    print("[serve] sample:", out[0][:10])


if __name__ == "__main__":
    main()
